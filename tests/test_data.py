"""Data pipeline: determinism, host sharding, prefetch, mixtures."""
from __future__ import annotations

import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.data import MixtureDataset, Prefetcher, SyntheticLM


def test_batch_is_pure_function_of_step():
    ds = SyntheticLM(vocab_size=256, seq_len=32, global_batch=8, seed=7)
    a = ds.batch_at(5)
    b = ds.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_targets_are_shifted_tokens():
    ds = SyntheticLM(vocab_size=128, seq_len=16, global_batch=4)
    b = ds.batch_at(0)
    assert b["tokens"].shape == (4, 16) and b["targets"].shape == (4, 16)
    # same underlying stream shifted by one
    full_a = ds.batch_at(0)
    assert (full_a["tokens"][:, 1:] == full_a["targets"][:, :-1]).all()


def test_tokens_in_range():
    ds = SyntheticLM(vocab_size=100, seq_len=64, global_batch=4)
    for step in (0, 1, 17):
        b = ds.batch_at(step)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_host_slice_partitions_batch():
    ds = SyntheticLM(vocab_size=64, seq_len=8, global_batch=8)
    g = ds.batch_at(3)
    parts = [ds.host_slice(g, h, 4) for h in range(4)]
    rebuilt = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(rebuilt, g["tokens"])


def test_stream_not_iid():
    """The Markov structure must be learnable: adjacent tokens correlate."""
    ds = SyntheticLM(vocab_size=1024, seq_len=256, global_batch=8, seed=0)
    t = ds.batch_at(0)["tokens"].astype(np.int64)
    deltas = np.abs(np.diff(t, axis=1))
    deltas = np.minimum(deltas, 1024 - deltas)  # circular distance
    # banded walk keeps most steps tiny vs uniform expectation (~256)
    assert np.median(deltas) < 64


def test_state_roundtrip():
    ds = SyntheticLM(vocab_size=64, seq_len=8, global_batch=2, seed=9)
    st_ = ds.state(41)
    ds2, step = SyntheticLM.from_state(st_, vocab_size=64, seq_len=8,
                                       global_batch=2)
    assert step == 41
    np.testing.assert_array_equal(ds.batch_at(41)["tokens"],
                                  ds2.batch_at(41)["tokens"])


def test_prefetcher_order_and_restart():
    ds = SyntheticLM(vocab_size=64, seq_len=8, global_batch=2)
    pf = Prefetcher(ds, start=0, depth=2)
    seq = [pf.get() for _ in range(4)]
    pf.close()
    assert [s for s, _ in seq] == [0, 1, 2, 3]
    # restart from step 2 replays the same stream (determinism)
    pf2 = Prefetcher(ds, start=2, depth=2)
    s2, b2 = pf2.get()
    pf2.close()
    assert s2 == 2
    np.testing.assert_array_equal(b2["tokens"], seq[2][1]["tokens"])


def test_mixture_deterministic_and_mixed():
    srcs = [SyntheticLM(64, 8, 16, seed=i) for i in range(2)]
    mix = MixtureDataset(srcs, weights=[0.5, 0.5], seed=3)
    a, b = mix.batch_at(4), mix.batch_at(4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # rows must come from both sources over a few steps
    matches = [0, 0]
    for step in range(3):
        m = mix.batch_at(step)
        for i, s in enumerate(srcs):
            sb = s.batch_at(step)
            matches[i] += sum((m["tokens"][r] == sb["tokens"][r]).all()
                              for r in range(16))
    assert matches[0] > 0 and matches[1] > 0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 64))
def test_property_purity(step, seed):
    ds1 = SyntheticLM(128, 16, 4, seed=seed)
    ds2 = SyntheticLM(128, 16, 4, seed=seed)
    np.testing.assert_array_equal(ds1.batch_at(step)["tokens"],
                                  ds2.batch_at(step)["tokens"])
